"""A two-tier fleet: edge aggregators between the devices and the cloud.

Real fleets are not flat: phones in a depot sync with the depot's edge
server over cheap local links, and only the edge servers talk to the
coordinator over the expensive backhaul. The staged sync kernel expresses
exactly that as configuration (``HierarchyConfig``): the flat protocol you
already know runs *inside each cluster* against its edge aggregator, and a
second operator — with its own cadence, divergence threshold, and payload
size — runs among the aggregators. Both tiers live inside the scanned
round, and the per-link bytes ledger prices each tier at its own payload
size, so a quantized backhaul stays exact.

This walkthrough puts twelve learners in three clusters on a flaky
network and compares flat dynamic averaging against two-tier dynamic
averaging with a looser inter-tier threshold and a 1-byte-per-param
(8-bit-quantized) backhaul.

    PYTHONPATH=src python examples/hierarchical_fleet.py
"""
import numpy as np

from repro.config import (
    HierarchyConfig, NetworkConfig, ProtocolConfig, TrainConfig, get_arch,
)
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

M, CLUSTERS = 12, 3

FLEET = NetworkConfig(act_prob=0.8, link_classes=("wifi", "lte"))

FLAT = ProtocolConfig(kind="dynamic", b=5, delta=0.5)

TWO_TIER = ProtocolConfig(
    kind="dynamic", b=5, delta=0.5,        # intra: devices <-> edge server
    tiers=HierarchyConfig(
        num_clusters=CLUSTERS,
        inter=ProtocolConfig(kind="dynamic", b=10, delta=1.0,
                             bytes_per_param=1),   # quantized backhaul
        link_class="wired",
    ),
)


def main():
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)

    print(f"fleet: m={M} in {CLUSTERS} clusters, act_prob={FLEET.act_prob}, "
          f"links={FLEET.link_classes}, backhaul=wired (8-bit payload)\n")

    for name, proto in [("flat dynamic", FLAT), ("two-tier dynamic",
                                                 TWO_TIER)]:
        dl, _ = run_protocol_training(
            loss_fn, init_fn, SyntheticMNIST(seed=0, image_size=14),
            m=M, rounds=150, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.1),
            batch=10, seed=0, network=FLEET)
        ledger = dl.per_link_bytes()
        member, uplink = ledger[:M].sum(), ledger[M:].sum()
        assert int(ledger.sum()) == dl.comm_bytes()   # the ledger balances
        print(f"{name:17s} loss={dl.cumulative_loss:9.1f} "
              f"total={dl.comm_bytes() / 1e6:6.1f}MB "
              f"member_links={member / 1e6:6.1f}MB "
              f"coordinator_uplinks="
              f"{(uplink if len(ledger) > M else member) / 1e6:6.1f}MB "
              f"net_time={dl.network_time:6.2f}s")

    print("\nthe edge tier absorbs the chatter: intra-cluster violations "
          "settle against the local aggregator, and only the aggregators' "
          "(quantized) models cross the backhaul — the ledger prices every "
          "link exactly, per tier.")


if __name__ == "__main__":
    main()
