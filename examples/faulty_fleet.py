"""A faulty fleet: crashes, adversaries, and the robust pipeline.

The paper's learners are reliable: always up, always honest. Real
fleets aren't — nodes crash and rejoin having lost local state, radios
corrupt payloads into NaNs, and a compromised node can ship
sign-flipped parameters on purpose. Attaching a ``FaultConfig``
injects all of that INSIDE the scanned round, every fault a pure
function of ``(fault_seed, t)`` (``repro.network.faults``), and the
defenses are just registered stages (``repro.core.sync.robust``):

* plain ``dynamic`` averages whatever arrives — one sign-flipper per
  five learners drags every sync, and the honest fleet never converges;
* ``robust_dynamic`` swaps the mean for a trimmed mean, quarantines
  rows that are non-finite or far from the reference, and warm-starts
  them from the reference model — crashed learners rejoin cold and get
  healed by the same path that resets the adversaries every sync.

The walkthrough runs both pipelines under the SAME fault schedule
(crash episodes + 20% sign-flipping adversaries), streams them through
the telemetry plane, and rebuilds the observatory fault card — faulty
learners per round, quarantine and recovery counts — from the JSONL
alone. Progress goes through the structured event logger, the same
stream a launcher would scrape.

    PYTHONPATH=src python examples/faulty_fleet.py [--smoke]
"""
import argparse
import os
import tempfile

import numpy as np

from repro.config import (
    FaultConfig, ProtocolConfig, TelemetryConfig, TrainConfig, get_arch,
)
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.network import faults as nf
from repro.telemetry import console_handler, get_logger
from repro.telemetry.observatory import load_run, summarize
from repro.train.loop import run_protocol_training

M = 10
# one in five learners is a sign-flipping adversary, and every
# 16-round window each learner has a 15% chance of a 2-4 round crash
# it rejoins from COLD (lost params, optimizer state, sync state)
FAULTS = FaultConfig(fault_seed=11, byzantine_frac=0.2,
                     byzantine_mode="sign_flip",
                     crash_prob=0.15, crash_every=16,
                     outage_min=2, outage_max=4)


def run_one(name, proto, rounds, jsonl, log):
    cfg = get_arch("drift_mlp", smoke=True)
    dl, _ = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=M, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=0, faults=FAULTS,
        telemetry=TelemetryConfig(path=jsonl))
    dl.recorder.close()
    honest = ~np.asarray(nf.byzantine_mask(FAULTS, M))
    honest_loss = float(dl.cumulative_loss_per_learner[honest].sum())
    log.event("fleet_run_done", protocol=name, rounds=rounds,
              syncs=dl.comm_totals["syncs"],
              honest_loss=round(honest_loss, 1),
              honest_finite=bool(np.isfinite(honest_loss)))
    return honest_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few rounds (CI smoke)")
    args = ap.parse_args()
    rounds = 32 if args.smoke else 160

    log = get_logger()
    handler = log.add_handler(console_handler())
    out_dir = tempfile.mkdtemp(prefix="faulty_fleet_")

    n_adv = int(round(FAULTS.byzantine_frac * M))
    print(f"fleet: m={M}, {n_adv} sign-flipping adversaries, crash "
          f"episodes at p={FAULTS.crash_prob} per {FAULTS.crash_every}"
          f"-round window ({FAULTS.outage_min}-{FAULTS.outage_max} rounds "
          f"down, rejoin COLD)\n")

    losses = {}
    try:
        for name, proto in [
            # b=1: check the divergence gate every round — at the
            # default b=10 the adversaries drift uncontested between
            # checks and even the robust pipeline heals too late
            ("dynamic (mean)", ProtocolConfig(kind="dynamic", b=1,
                                              delta=0.5)),
            ("robust_dynamic", ProtocolConfig(kind="robust_dynamic", b=1,
                                              delta=0.5)),
        ]:
            jsonl = os.path.join(out_dir, name.split()[0] + ".jsonl")
            losses[name] = run_one(name, proto, rounds, jsonl, log)

            # the observatory's view, from the stream alone: the fault
            # card — how many learners were under a fault each round,
            # and (for the robust pipeline) the quarantine/recovery
            # ledger the health counters feed
            card = summarize(load_run(jsonl))
            faults = card.get("faults", {})
            line = (f"{name:16s} honest_loss={losses[name]:12.1f} "
                    f"syncs={card['cum_syncs']:3d} "
                    f"faulty_rounds={faults.get('faulty_rounds', 0)}"
                    f"/{rounds} max_faulty={faults.get('max_faulty', 0)}")
            if "total_recovered" in faults:
                line += (f" quarantined_last="
                         f"{faults['quarantined_last']} "
                         f"recovered_total={faults['total_recovered']}")
            print(line)
    finally:
        log.remove_handler(handler)

    print("\nthe plain mean averaged the flipped rows straight into "
          "every commit — the honest fleet paid for each sync; the "
          "robust pipeline trimmed them out of the aggregate, "
          "quarantined them at commit, and warm-started every crashed "
          "learner from the reference. Same engine, same scan: the "
          "defenses are just registered stages.")
    print("faulty_fleet_done")


if __name__ == "__main__":
    main()
