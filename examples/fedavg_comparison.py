"""Dynamic averaging vs Federated Averaging (McMahan et al. 2017).

FedAvg reduces periodic averaging's bill by sub-sampling a C-fraction of
learners per round — but it still pays every round. Dynamic averaging pays
only when the model configuration diverges, so as the learners converge its
bill flattens while FedAvg's keeps growing linearly (the paper's Fig. 5.2).

    PYTHONPATH=src python examples/fedavg_comparison.py
"""
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

import jax


def main():
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)

    print(f"{'protocol':16s} {'comm':>10s} {'cumloss':>9s} {'acc':>6s}   "
          f"comm curve (KB at 25% / 50% / 75% / 100% of training)")
    for name, proto in [
        ("fedavg C=0.3", ProtocolConfig(kind="fedavg", b=10, fedavg_c=0.3)),
        ("fedavg C=0.7", ProtocolConfig(kind="fedavg", b=10, fedavg_c=0.7)),
        ("dynamic Δ=1.2", ProtocolConfig(kind="dynamic", b=10, delta=1.2)),
    ]:
        src = SyntheticMNIST(seed=0, image_size=14)
        dl, traj = run_protocol_training(
            loss_fn, init_fn, src, m=10, rounds=260, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.1),
            batch=10, seed=0, record_every=10)
        test = src.sample(jax.random.PRNGKey(999), 512)
        acc = float(cnn_accuracy(cfg, dl.mean_model(), test))
        curve = traj.cumulative_bytes
        q = [curve[len(curve) * i // 4 - 1] // 1024 for i in (1, 2, 3, 4)]
        print(f"{name:16s} {dl.comm_bytes()/1e6:8.2f}MB "
              f"{dl.cumulative_loss:9.1f} {acc:6.3f}   {q}")


if __name__ == "__main__":
    main()
