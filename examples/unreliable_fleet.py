"""An unreliable fleet: dynamic averaging when the network fights back.

The paper motivates dynamic averaging with fleets of cars and phones —
devices that drop off the network, straggle, and pay real bandwidth for
every model they move. This walkthrough puts ten learners on exactly that
network (``NetworkConfig``):

* 60% per-round availability, with three stragglers at 30%
* a random-geometric peer overlay that re-draws every 20 rounds (mobility)
* mixed wifi/lte links, so a synchronization's wall-clock is set by the
  slowest participating link

and compares three protocols end to end — periodic averaging (pays full
fleet syncs), dynamic averaging (pays only on divergence violations), and
gossip (no coordinator at all, averages over the mobile overlay). All
rounds run through the scanned engine: availability masks, mobility
re-draws and link costs are sampled inside ``lax.scan``, one compiled
program per chunk.

    PYTHONPATH=src python examples/unreliable_fleet.py
"""
import jax

from repro.config import NetworkConfig, ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

FLEET = NetworkConfig(
    topology="geometric", geo_radius=0.6, redraw_every=20,
    act_prob=0.6, straggler_frac=0.3, straggler_act_prob=0.3,
    link_classes=("wifi", "lte"),
)


def main():
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = SyntheticMNIST(seed=0, image_size=14)

    print(f"fleet: m=10, act_prob={FLEET.act_prob}, "
          f"{FLEET.straggler_frac:.0%} stragglers, "
          f"topology={FLEET.topology} (re-drawn every "
          f"{FLEET.redraw_every} rounds), links={FLEET.link_classes}\n")

    for name, proto in [
        ("periodic b=10", ProtocolConfig(kind="periodic", b=10)),
        ("dynamic Δ=0.7", ProtocolConfig(kind="dynamic", b=10, delta=0.7)),
        ("gossip  b=10", ProtocolConfig(kind="gossip", b=10)),
    ]:
        dl, _ = run_protocol_training(
            loss_fn, init_fn, src, m=10, rounds=150, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.1),
            batch=10, seed=0, network=FLEET)
        test = src.sample(jax.random.PRNGKey(999), 512)
        acc = float(cnn_accuracy(cfg, dl.mean_model(), test))
        busiest = int(dl.per_link_bytes().argmax())
        print(f"{name:14s} loss={dl.cumulative_loss:9.1f} "
              f"comm={dl.comm_bytes() / 1e6:7.1f}MB "
              f"net_time={dl.network_time:7.2f}s "
              f"reachable={dl.mean_active():.0%} "
              f"accuracy={acc:.3f} "
              f"busiest_link=#{busiest} "
              f"({dl.per_link_bytes()[busiest] / 1e6:.1f}MB)")

    print("\ndynamic averaging keeps its communication advantage under "
          "dropout: violations simply wait for the violator to come back "
          "in reach, while periodic pays for every reachable learner every "
          "b rounds; gossip needs no coordinator but its mixing (and its "
          "bytes) track the mobile overlay's density.")


if __name__ == "__main__":
    main()
