"""Concept drift: dynamic averaging invests communication where it matters.

A fleet of learners trains on a random-graphical-model stream (paper
App. A.3). We force two concept drifts and print the per-window sync rate:
dynamic averaging goes quiet between drifts and bursts right after them,
while periodic averaging pays the same bill all the time.

Drift rounds are known up front, so the run is three scanned chunks
(``run_chunk``) with a ``force_drift`` between them; the per-round sync
history is reconstructed from the chunks' stacked comm records.

    PYTHONPATH=src python examples/concept_drift.py
"""
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_drift_segments

ROUNDS, WINDOW = 240, 20
DRIFTS = (80, 160)


def main():
    cfg = get_arch("drift_mlp")
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)

    for name, proto in [
        ("periodic b=10", ProtocolConfig(kind="periodic", b=10)),
        ("dynamic Δ=0.5", ProtocolConfig(kind="dynamic", b=2, delta=0.5)),
    ]:
        src = GraphicalModelStream(seed=1, drift_prob=0.0)
        streams = LearnerStreams(src, 8, batch=10, seed=0)
        dl = DecentralizedLearner(
            loss_fn, init_fn, 8, proto,
            TrainConfig(optimizer="sgd", learning_rate=0.1))
        sync_hist, _ = run_drift_segments(dl, streams, src, ROUNDS, DRIFTS)
        print(f"\n{name}: total syncs {sync_hist[-1]}, "
              f"comm {dl.comm_bytes()/1e6:.1f}MB, "
              f"cumulative loss {dl.cumulative_loss:.0f}")
        print("  syncs per 20-round window "
              "(drifts at rounds 80 and 160 marked *):")
        row = []
        for w in range(0, ROUNDS, WINDOW):
            n = sync_hist[min(w + WINDOW, ROUNDS) - 1] - (
                sync_hist[w - 1] if w else 0)
            mark = "*" if any(w <= d < w + WINDOW for d in DRIFTS) else " "
            row.append(f"{mark}{n:2d}")
        print("  [" + " ".join(row) + "]")


if __name__ == "__main__":
    main()
