"""An asynchronous fleet: event-driven averaging over slow links.

The paper's fleets synchronize in lock-step rounds. Real radio links
don't cooperate: at a 100 kB model and a 1-second round budget, an LTE
link's round trip fits inside the round, but an edge (2G-fallback) link
needs two full seconds — its exchanges are still IN FLIGHT when the next
round starts. Attaching an ``AsyncConfig`` rewrites any protocol onto
the event-driven network timeline (``repro.core.sync.async_sync``):

* every learner runs on a LOCAL clock that only advances while it is
  idle — a slow learner's cadence stretches by its flight times;
* a triggered exchange flies ``k = ceil(round_trip/budget) - 1`` whole
  rounds through a bounded arrival ring, and the learner participates
  in a synchronization only when its message lands;
* the whole timeline is pure in ``(seed, t)`` and runs INSIDE the
  scanned engine — one compiled program per chunk, no Python events.

The walkthrough runs the lte/edge fleet under the cadence trigger and
the divergence trigger, streams both runs through the telemetry plane,
and rebuilds the observatory run cards — including the in-flight /
staleness-age histograms — from the JSONL alone. Progress goes through
the structured event logger (``repro.telemetry``), the same stream a
launcher would scrape.

    PYTHONPATH=src python examples/async_fleet.py [--smoke]
"""
import argparse
import json
import os
import tempfile

from repro.config import (
    AsyncConfig, NetworkConfig, ProtocolConfig, TelemetryConfig,
    TrainConfig, get_arch,
)
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.telemetry import console_handler, get_logger
from repro.telemetry.observatory import load_run, summarize
from repro.train.loop import run_protocol_training

FLEET = NetworkConfig(link_classes=("lte", "edge"), act_prob=0.85)
TIMELINE = AsyncConfig(round_budget=1.0, payload_bytes=100_000)


def run_one(name, proto, rounds, jsonl, log):
    cfg = get_arch("drift_mlp", smoke=True)
    dl, _ = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=8, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=0, network=FLEET, async_net=TIMELINE,
        telemetry=TelemetryConfig(path=jsonl, per_link=True))
    dl.recorder.close()
    log.event("fleet_run_done", protocol=name, rounds=rounds,
              syncs=dl.comm_totals["syncs"], bytes=dl.comm_bytes(),
              net_time_s=round(dl.network_time, 2))
    return dl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="few rounds (CI smoke)")
    args = ap.parse_args()
    rounds = 32 if args.smoke else 160

    log = get_logger()
    handler = log.add_handler(console_handler())
    out_dir = tempfile.mkdtemp(prefix="async_fleet_")

    print(f"fleet: m=8, links={FLEET.link_classes}, "
          f"act_prob={FLEET.act_prob}, round budget "
          f"{TIMELINE.round_budget}s at a {TIMELINE.payload_bytes/1e3:.0f}"
          f"kB payload -> edge exchanges fly 1 round, lte lands "
          f"synchronously\n")

    try:
        for name, proto in [
            ("periodic b=2", ProtocolConfig(kind="periodic", b=2)),
            ("dynamic Δ=0.5", ProtocolConfig(kind="dynamic", b=2,
                                             delta=0.5)),
        ]:
            jsonl = os.path.join(
                out_dir, name.split()[0] + ".jsonl")
            dl = run_one(name, proto, rounds, jsonl, log)

            # the observatory's view, from the stream alone: the run
            # card now carries the timeline — per-round in-flight
            # counts and the chunk-end age/clock histograms
            card = summarize(load_run(jsonl))
            ages = card.get("state_ages", {})
            print(f"{name:14s} loss={card['cum_loss']:9.1f} "
                  f"syncs={card['cum_syncs']:3d} "
                  f"comm={card['cum_bytes']/1e6:6.1f}MB "
                  f"net_time={card['net_time_s']:7.2f}s")
            print(f"{'':14s} in-flight last={card.get('inflight_last', 0)} "
                  f"oldest age={card.get('max_age_last', 0)} "
                  f"age histogram="
                  f"{json.dumps(ages.get('age', {}).get('hist', {}))} "
                  f"in-flight histogram="
                  f"{json.dumps(ages.get('inflight', {}).get('hist', {}))}")
    finally:
        log.remove_handler(handler)

    print("\nthe cadence trigger keeps paying for every tick — the edge "
          "learners just pay it a round late; the divergence trigger "
          "only launches when a model actually drifts, so the slow links "
          "stay quiet until the violation lands. Same engine, same scan: "
          "the timeline is just trigger state.")
    print("async_fleet_done")


if __name__ == "__main__":
    main()
