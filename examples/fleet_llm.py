"""End-to-end driver: decentralized training of a ~100M-param LM.

Four "pods" (learners) train a 12-layer / d_model=768 llama-family model on
disjoint bigram-Markov token streams with the dynamic averaging protocol —
the full production path (model def -> learner-stacked train state -> the
SPMD dynamic-averaging step from repro.core.distributed) for a few hundred
steps on CPU, with checkpointing.

    PYTHONPATH=src python examples/fleet_llm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_pytree
from repro.config import ModelConfig, ProtocolConfig, TrainConfig, get_arch
from repro.core.distributed import (
    init_dynamic_state, make_dynamic_train_step)
from repro.data.synthetic import TokenStream
from repro.models.model import init_lm_params, lm_loss

M = 4                      # learners ("pods")
B, S = 4, 128              # per-learner batch


def fleet_model(big: bool = False) -> ModelConfig:
    """~100M-param llama-family model (--big) or a ~25M variant whose
    60-step run finishes in minutes on one CPU core."""
    base = get_arch("llama3-8b")
    if big:
        return dataclasses.replace(
            base, name="fleet-llm-100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=8192,
            dtype="float32")
    return dataclasses.replace(
        base, name="fleet-llm-25m", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=1408, vocab_size=8192,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="a few hundred steps converge; the default keeps "
                         "single-core CPU runtime in minutes")
    ap.add_argument("--delta", type=float, default=5.0)
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--big", action="store_true",
                    help="~100M-param model (the full driver config)")
    args = ap.parse_args()

    cfg = fleet_model(args.big)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"m={M} learners, batch {B}x{S} tokens each")

    loss_fn = lambda p, b: lm_loss(cfg, p, b)
    train = TrainConfig(optimizer="adam", learning_rate=3e-4)
    proto = ProtocolConfig(kind="dynamic", b=args.sync_every,
                           delta=args.delta)
    step = jax.jit(make_dynamic_train_step(loss_fn, proto, train, M))
    state = init_dynamic_state(
        lambda k: init_lm_params(cfg, k), jax.random.PRNGKey(0), M, train)

    streams = [TokenStream(seed=100 + i, vocab=cfg.vocab_size)
               for i in range(M)]
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for t in range(args.steps):
        key, sub = jax.random.split(key)
        batches = [s.sample(jax.random.fold_in(sub, i), B, S)
                   for i, s in enumerate(streams)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        state, metrics = step(state, batch)
        if (t + 1) % 20 == 0:
            print(f"step {t+1:4d} loss {float(metrics['loss']):.4f} "
                  f"syncs {int(state.syncs):3d} "
                  f"({(t+1)*M*B*S/(time.time()-t0):,.0f} tok/s)")

    save_pytree("experiments/fleet_llm_final.npz",
                {"params": jax.tree.map(lambda x: x[0], state.params),
                 "step": state.step})
    checks = max(int(state.checks), 1)
    print(f"\ndone: {int(state.syncs)}/{checks} condition checks triggered "
          f"averaging -> {100*int(state.syncs)/checks:.0f}% of the periodic "
          f"protocol's communication at the same cadence.")
    print("checkpoint: experiments/fleet_llm_final.npz")


if __name__ == "__main__":
    main()
