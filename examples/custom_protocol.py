"""Composing a protocol from registered stages — no kernel edits.

The protocol-spec API (``repro.core.sync.spec``) makes Π = (φ, σ) a
declarative composition: name one registered stage per slot, hand the
spec to the engine, done. This walkthrough builds three protocols on an
unreliable ten-learner fleet WITHOUT touching ``kernel.py`` or the
engine:

1. **bounded staleness** (the shipped ``"stale"`` preset): every learner
   carries a rounds-since-last-sync counter, accumulated against the
   availability mask inside the scan; the fleet averages the moment any
   reachable learner has gone τ rounds unsynchronized. Under full
   availability that is a period; under dropout it adapts — learners
   returning from darkness trigger the sync they missed.
2. **staleness-triggered FedAvg**: the same trigger composed with the
   random C-fraction cohort — a brand-new protocol in four lines.
3. the classic **dynamic averaging** baseline for comparison.

It then round-trips the custom spec through JSON — the exact artifact
``benchmarks/run.py --protocol <file>`` consumes and checkpoints store
next to their state — and re-runs it to show the restored spec drives
the engine identically.

    PYTHONPATH=src python examples/custom_protocol.py
"""
import jax

from repro.config import NetworkConfig, ProtocolConfig, TrainConfig, get_arch
from repro.core.sync import BOUNDED_STALENESS, ProtocolSpec
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

FLEET = NetworkConfig(act_prob=0.6, straggler_frac=0.3,
                      straggler_act_prob=0.3, link_classes=("wifi", "lte"))

PROTOCOLS = {
    # the shipped preset (``ProtocolConfig(kind="stale")`` works too; the
    # spec form exposes the trigger's tau knob directly)
    "stale(tau=8)": BOUNDED_STALENESS.with_params(tau=8),
    # a NEW composition: the staleness trigger driving FedAvg's cohort
    "stale_fedavg": ProtocolSpec(
        trigger="staleness", cohort="fraction", commit="subset",
        params={"tau": 8, "fedavg_c": 0.4}, name="stale_fedavg"),
    # the paper's baseline
    "dynamic": ProtocolConfig(kind="dynamic", b=8, delta=0.5),
}


def run(name, proto, rounds=300):
    cfg = get_arch("mnist_cnn", smoke=True)
    src = SyntheticMNIST(seed=0, image_size=14)
    dl, traj = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        src, m=10, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer="sgd", learning_rate=0.1),
        batch=10, network=FLEET)
    test = src.sample(jax.random.PRNGKey(10_000), 512)
    acc = float(cnn_accuracy(cfg, dl.mean_model(), test))
    print(f"  {name:<14} acc={acc:.3f} syncs={dl.comm_totals['syncs']:>4} "
          f"bytes={dl.comm_bytes() / 1e6:7.1f}MB "
          f"net_time={dl.network_time:7.1f}s")
    return dl


def main():
    print("10 learners, 60% availability with stragglers, wifi/lte links")
    for name, proto in PROTOCOLS.items():
        run(name, proto)

    # --- serialize the custom composition and run it from its JSON form
    spec = PROTOCOLS["stale_fedavg"]
    blob = spec.to_json()
    print("\nstale_fedavg as the JSON `benchmarks/run.py --protocol` "
          "takes:\n" + blob)
    restored = ProtocolSpec.from_json(blob)
    assert restored == spec
    a = run("original", spec, rounds=100)
    b = run("from JSON", restored, rounds=100)
    assert a.comm_totals == b.comm_totals
    print("restored spec reproduces the run exactly")


if __name__ == "__main__":
    main()
