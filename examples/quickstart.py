"""Quickstart: decentralized training with dynamic model averaging.

Ten learners train the paper's MNIST CNN; the dynamic averaging protocol
(sigma_Delta) gates every synchronization on the model-divergence local
conditions, and we compare its communication bill against periodic
averaging at equal predictive performance.

``run_protocol_training`` executes the rounds through the scanned chunk
driver (``DecentralizedLearner.run_chunk``): each chunk of rounds is one
compiled ``lax.scan`` program, not one jitted dispatch per round.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training


def main():
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = SyntheticMNIST(seed=0, image_size=14)

    results = {}
    for name, proto in [
        ("periodic b=10", ProtocolConfig(kind="periodic", b=10)),
        ("dynamic Δ=0.7", ProtocolConfig(kind="dynamic", b=10, delta=0.7)),
    ]:
        dl, traj = run_protocol_training(
            loss_fn, init_fn, src, m=10, rounds=150, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.1),
            batch=10, seed=0)
        test = src.sample(jax.random.PRNGKey(999), 512)
        acc = float(cnn_accuracy(cfg, dl.mean_model(), test))
        results[name] = (dl.cumulative_loss, dl.comm_bytes(), acc)
        print(f"{name:16s} cumulative_loss={dl.cumulative_loss:9.1f} "
              f"comm={dl.comm_bytes()/1e6:8.1f}MB accuracy={acc:.3f}")

    (_, comm_p, _), (_, comm_d, _) = results.values()
    print(f"\ndynamic averaging used {100 * (1 - comm_d / comm_p):.0f}% "
          f"less communication than periodic averaging.")


if __name__ == "__main__":
    main()
